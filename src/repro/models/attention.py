"""Attention: GQA projections + three execution strategies.

  * ``full``   — materialised scores with mask; cheapest HLO for short train
                 sequences (TP over heads + remat keep it in budget).
  * ``brick``  — flop-exact blocked attention: a ``lax.scan`` over the
                 *statically enumerated* list of (q-chunk, kv-chunk) bricks that
                 are actually needed under the causal/sliding-window mask, with
                 online softmax.  Peak memory is O(S·D) + one brick.  This is
                 the jnp twin of the Pallas flash kernel.
  * ``decode`` — single-token attention against a KV cache.  When the cache's
                 sequence dim is sharded (long-context serving) the computation
                 runs as a shard_map flash-decode: each shard computes partial
                 (m, l, o) and combines with psum/pmax — no cache all-gather.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import (apply_mrope, apply_rope, norm_spec,
                                 rms_norm, row_parallel_proj as L_row_parallel)
from repro.parallel import sharding as shlib
from repro.parallel.sharding import ParamSpec, shard_act

NEG_INF = -1e30


# --------------------------------------------------------------------------- #
# Parameter specs
# --------------------------------------------------------------------------- #
def attn_specs(cfg: ModelConfig, heads: Optional[int] = None,
               kv_heads: Optional[int] = None, cross: bool = False) -> dict:
    h = heads or cfg.num_heads
    kh = kv_heads or cfg.num_kv_heads
    d = cfg.head_dim
    specs = {
        "wq": ParamSpec((cfg.d_model, h, d), ("embed", "heads", None)),
        "wk": ParamSpec((cfg.d_model, kh, d), ("embed", "kv_heads", None)),
        "wv": ParamSpec((cfg.d_model, kh, d), ("embed", "kv_heads", None)),
        "wo": ParamSpec((h, d, cfg.d_model), ("heads", None, "embed")),
    }
    if cfg.qk_norm and not cross:
        specs["q_norm"] = norm_spec(d)
        specs["k_norm"] = norm_spec(d)
    return specs


def _softcap(scores: jax.Array, cap: float) -> jax.Array:
    if cap and cap > 0.0:
        return cap * jnp.tanh(scores / cap)
    return scores


# --------------------------------------------------------------------------- #
# full-scores attention (train path for short S)
# --------------------------------------------------------------------------- #
def full_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                   causal: bool = True, window: int = 0,
                   q_offset: int = 0, softcap: float = 0.0) -> jax.Array:
    """q: (B, Sq, Hq, D); k/v: (B, Skv, Hkv, D).  Returns (B, Sq, Hq, D)."""
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    q5 = q.reshape(B, Sq, Hkv, G, D)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q5, k) / math.sqrt(D)
    scores = _softcap(scores, softcap).astype(jnp.float32)
    qpos = q_offset + jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(B, Sq, Hq, D)


# --------------------------------------------------------------------------- #
# brick-scan attention (flop-exact flash, jnp)
# --------------------------------------------------------------------------- #
def _brick_list(nq: int, nk: int, cq: int, ck: int, causal: bool,
                window: int, q_offset: int) -> list:
    """Statically enumerate needed (i, j) bricks under the mask."""
    pairs = []
    for i in range(nq):
        q_lo, q_hi = q_offset + i * cq, q_offset + (i + 1) * cq - 1
        for j in range(nk):
            k_lo, k_hi = j * ck, (j + 1) * ck - 1
            if causal and k_lo > q_hi:
                continue
            if window and k_hi <= q_lo - window:
                continue
            pairs.append((i, j))
    return pairs


def brick_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0, q_offset: int = 0,
                    cq: int = 1024, ck: int = 2048,
                    softcap: float = 0.0) -> jax.Array:
    """Blocked online-softmax attention via scan over needed bricks only."""
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    cq = min(cq, Sq)
    ck = min(ck, Skv)
    # pad seq lens to multiples of chunks
    pq = (-Sq) % cq
    pk = (-Skv) % ck
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    Sq_p, Skv_p = Sq + pq, Skv + pk
    nq, nk = Sq_p // cq, Skv_p // ck
    pairs = _brick_list(nq, nk, cq, ck, causal, window, q_offset)
    # pad kv beyond Skv is masked via kpos >= Skv check below
    qc = q.reshape(B, nq, cq, Hkv, G, D)
    kc = k.reshape(B, nk, ck, Hkv, D)
    vc = v.reshape(B, nk, ck, Hkv, D)
    scale = 1.0 / math.sqrt(D)

    acc0 = jnp.zeros((nq, B, cq, Hkv, G, D), jnp.float32)
    m0 = jnp.full((nq, B, cq, Hkv, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((nq, B, cq, Hkv, G), jnp.float32)

    iis = jnp.asarray([p[0] for p in pairs], jnp.int32)
    jjs = jnp.asarray([p[1] for p in pairs], jnp.int32)

    def body(carry, ij):
        acc, m, l = carry
        i, j = ij
        qi = jax.lax.dynamic_index_in_dim(qc, i, axis=1, keepdims=False)
        kj = jax.lax.dynamic_index_in_dim(kc, j, axis=1, keepdims=False)
        vj = jax.lax.dynamic_index_in_dim(vc, j, axis=1, keepdims=False)
        s = jnp.einsum("bqkgd,bskd->bqkgs", qi, kj) * scale
        s = _softcap(s, softcap).astype(jnp.float32)
        qpos = q_offset + i * cq + jnp.arange(cq)[:, None]
        kpos = j * ck + jnp.arange(ck)[None, :]
        mask = kpos < Skv
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask[:, None, None, :], s, NEG_INF)
        mi = jax.lax.dynamic_index_in_dim(m, i, 0, keepdims=False)
        li = jax.lax.dynamic_index_in_dim(l, i, 0, keepdims=False)
        ai = jax.lax.dynamic_index_in_dim(acc, i, 0, keepdims=False)
        s_max = jnp.max(s, axis=-1)                       # (B, cq, Hkv, G)
        m_new = jnp.maximum(mi, jnp.transpose(s_max, (0, 1, 2, 3)))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(mi - m_new)
        l_new = li * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bqkgs,bskd->bqkgd", p.astype(q.dtype), vj)
        a_new = ai * corr[..., None] + pv.astype(jnp.float32)
        acc = jax.lax.dynamic_update_index_in_dim(acc, a_new, i, 0)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, i, 0)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, i, 0)
        return (acc, m, l), None

    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), (iis, jjs))
    out = acc / jnp.maximum(l[..., None], 1e-37)
    out = jnp.transpose(out, (1, 0, 2, 3, 4, 5)).reshape(B, Sq_p, Hq, D)
    return out[:, :Sq].astype(q.dtype)


# --------------------------------------------------------------------------- #
# decode attention (flash-decode, seq-shard aware)
# --------------------------------------------------------------------------- #
def _decode_attn_local(q, k, v, kpos, t, window, softcap):
    """Partial attention on a local KV shard -> (o, m, l) un-normalised.

    kpos: (B, S_loc) global positions of cache slots; t: (B,) per-sequence
    current positions (continuous batching gives every slot its own).
    """
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    q5 = q.reshape(B, Sq, Hkv, G, D)
    s = jnp.einsum("bqkgd,bskd->bqkgs", q5, k) / math.sqrt(D)
    s = _softcap(s, softcap).astype(jnp.float32)
    # kpos < 0 marks ring-buffer slots not yet written (pre-wrap)
    mask = (kpos <= t[:, None]) & (kpos >= 0)
    if window:
        mask &= kpos > (t[:, None] - window)
    s = jnp.where(mask[:, None, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bqkgs,bskd->bqkgd", p.astype(q.dtype), v).astype(jnp.float32)
    return o, m, l


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     t: jax.Array, *, window: int = 0, ring: bool = False,
                     softcap: float = 0.0) -> jax.Array:
    """q: (B, 1, Hq, D); caches: (B, S_c, Hkv, D); t = per-seq positions (B,).

    If the cache sequence dim is sharded on the current mesh, runs as a
    shard_map flash-decode with psum/pmax combination across the seq axes.
    ``ring=True`` treats the cache as a ring buffer of size S_c (sliding
    window): global position of slot s is t - ((t - s) mod S_c).
    """
    B, Sc = k_cache.shape[0], k_cache.shape[1]
    mesh = shlib.current_mesh()
    rules = shlib.current_rules()
    t = jnp.broadcast_to(jnp.atleast_1d(jnp.asarray(t)), (B,))

    def kpos_of(slots, t_):
        # slots: (S_loc,); returns (B, S_loc) global positions
        if ring:
            return t_[:, None] - jnp.mod(t_[:, None] - slots[None, :], Sc)
        return jnp.broadcast_to(slots[None, :], (t_.shape[0], slots.shape[0]))

    if mesh is None:
        slots = jnp.arange(Sc)
        o, m, l = _decode_attn_local(q, k_cache, v_cache, kpos_of(slots, t),
                                     t, window, softcap)
        out = o / jnp.maximum(l[..., None], 1e-37)
        return out.reshape(q.shape).astype(q.dtype)

    cache_spec = shlib.logical_to_mesh_axes(
        mesh, k_cache.shape, ("batch", "kv_seq", "kv_heads", None), rules)
    seq_axes = cache_spec[1]
    seq_axes = () if seq_axes is None else (
        (seq_axes,) if isinstance(seq_axes, str) else tuple(seq_axes))
    batch_axes = cache_spec[0]
    batch_axes = () if batch_axes is None else (
        (batch_axes,) if isinstance(batch_axes, str) else tuple(batch_axes))

    if not seq_axes:
        slots = jnp.arange(Sc)
        q = shard_act(q, "batch", None, "heads", None)
        k_cache = jax.lax.with_sharding_constraint(
            k_cache, jax.sharding.NamedSharding(mesh, cache_spec))
        v_cache = jax.lax.with_sharding_constraint(
            v_cache, jax.sharding.NamedSharding(mesh, cache_spec))
        o, m, l = _decode_attn_local(q, k_cache, v_cache, kpos_of(slots, t),
                                     t, window, softcap)
        out = o / jnp.maximum(l[..., None], 1e-37)
        return out.reshape(q.shape).astype(q.dtype)

    n_seq = int(np.prod([mesh.shape[a] for a in seq_axes]))
    Sc_loc = Sc // n_seq
    bspec = (None if not batch_axes else
             (batch_axes[0] if len(batch_axes) == 1 else tuple(batch_axes)))
    sspec = seq_axes[0] if len(seq_axes) == 1 else tuple(seq_axes)

    from repro.compat import shard_map

    def local_fn(q_l, k_l, v_l, t_l):
        # shard index along the flattened seq axes
        idx = jnp.int32(0)
        for a in seq_axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        slots = idx * Sc_loc + jnp.arange(Sc_loc)
        o, m, l = _decode_attn_local(q_l, k_l, v_l, kpos_of(slots, t_l),
                                     t_l, window, softcap)
        m_g = jax.lax.pmax(m, seq_axes)
        corr = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * corr, seq_axes)
        o_g = jax.lax.psum(o * corr[..., None], seq_axes)
        return o_g / jnp.maximum(l_g[..., None], 1e-37)

    out = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(bspec, None, None, None), P(bspec, sspec, None, None),
                  P(bspec, sspec, None, None), P(bspec)),
        out_specs=P(bspec, None, None, None, None),
        check_vma=False,
    )(q, k_cache, v_cache, t)
    B_, Sq_, Hkv_, G_, D_ = out.shape
    return out.reshape(B_, Sq_, Hkv_ * G_, D_).astype(q.dtype)


# --------------------------------------------------------------------------- #
# Block-level glue: projections + rope + cache handling
# --------------------------------------------------------------------------- #
def cache_specs(cfg: ModelConfig, batch: int, cache_len: int,
                heads: Optional[int] = None, kv_heads: Optional[int] = None
                ) -> dict:
    kh = kv_heads or cfg.num_kv_heads
    return {
        "k": ParamSpec((batch, cache_len, kh, cfg.head_dim),
                       ("batch", "kv_seq", "kv_heads", None),
                       dtype=cfg.act_dtype, init="zeros"),
        "v": ParamSpec((batch, cache_len, kh, cfg.head_dim),
                       ("batch", "kv_seq", "kv_heads", None),
                       dtype=cfg.act_dtype, init="zeros"),
    }


def _q_col_parallel(x: jax.Array, wq: jax.Array):
    """Q projection with the seq all-gather inside shard_map (its transpose
    is psum_scatter, killing the backward dx all-reduce).  None = fallback."""
    import numpy as np
    mesh = shlib.current_mesh()
    if mesh is None or "model" not in mesh.shape:
        return None
    mp = mesh.shape["model"]
    B, S = x.shape[0], x.shape[1]
    if mp == 1 or S % mp or wq.shape[1] % mp:
        return None
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp = int(np.prod([mesh.shape[a] for a in data_axes])) if data_axes else 1
    if data_axes and B % dp:
        return None
    bsp = (None if not data_axes else
           (data_axes[0] if len(data_axes) == 1 else data_axes))
    from repro.compat import shard_map

    def f(x_l, wq_l):
        xg = jax.lax.all_gather(x_l, "model", axis=1, tiled=True)
        return jnp.einsum("bsd,dhe->bshe", xg, wq_l)

    return shard_map(f, mesh=mesh,
                     in_specs=(P(bsp, "model", None), P(None, "model", None)),
                     out_specs=P(bsp, None, "model", None),
                     check_vma=False)(x, wq)


def _project_qkv(params: dict, x: jax.Array, cfg: ModelConfig,
                 positions, apply_pos: bool = True, tp_sp: bool = False):
    dt = x.dtype
    q = None
    if tp_sp:
        q = _q_col_parallel(x, params["wq"].astype(dt))
    if q is None:
        q = jnp.einsum("bsd,dhe->bshe", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhe->bshe", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhe->bshe", x, params["wv"].astype(dt))
    if cfg.qk_norm and "q_norm" in params:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    if apply_pos and cfg.head_dim % 2 == 0:
        if cfg.mrope:
            q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
            k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
        else:
            pos1 = positions if positions.ndim == 2 else positions[0]
            q = apply_rope(q, pos1, cfg.rope_theta)
            k = apply_rope(k, pos1, cfg.rope_theta)
    return q, k, v


def attention_block(params: dict, x: jax.Array, cfg: ModelConfig, *,
                    local: bool = False, mode: str = "train",
                    positions: Optional[jax.Array] = None,
                    cache: Optional[dict] = None, causal: bool = True,
                    index=None) -> Tuple[jax.Array, Optional[dict]]:
    """Self-attention sub-block.  Returns (out, new_cache)."""
    B, S, _ = x.shape
    window = cfg.window_size if local else 0
    if positions is None:
        base = jnp.arange(S) if mode != "decode" else jnp.asarray(index)[None]
        positions = jnp.broadcast_to(base, (B, S))

    q, k, v = _project_qkv(params, x, cfg, positions,
                           tp_sp=cfg.tp_sp and mode != "decode")
    # GQA head padding: when Hq doesn't divide the TP axis (e.g. 40 heads on
    # TP=16), pad the per-kv-head group so attention heads shard instead of
    # replicating 16x (the dominant waste for qwen3-14b / llama4-scout).
    pad_g = None
    if cfg.pad_attn_heads:
        mesh = shlib.current_mesh()
        tp = mesh.shape.get("model", 1) if mesh is not None else 1
        Hq, Hkv = q.shape[2], k.shape[2]
        if tp > 1 and Hq % tp:
            G = Hq // Hkv
            g_pad = G
            while (Hkv * g_pad) % tp and g_pad < G + tp:
                g_pad += 1
            if (Hkv * g_pad) % tp == 0:
                q5 = q.reshape(B, q.shape[1], Hkv, G, cfg.head_dim)
                q5 = jnp.pad(q5, ((0, 0), (0, 0), (0, 0), (0, g_pad - G),
                                  (0, 0)))
                q = q5.reshape(B, q.shape[1], Hkv * g_pad, cfg.head_dim)
                pad_g = (G, g_pad)
    q = shard_act(q, "batch", None, "heads", None)
    k = shard_act(k, "batch", None, "kv_heads", None)
    v = shard_act(v, "batch", None, "kv_heads", None)

    new_cache = None
    if mode == "decode":
        assert cache is not None
        Sc = cache["k"].shape[1]
        ring = bool(local and window and Sc <= window)
        idx_vec = jnp.broadcast_to(jnp.atleast_1d(jnp.asarray(index)), (B,))
        slot = jnp.mod(idx_vec, Sc) if ring else idx_vec
        k_cache = _cache_update(cache["k"], k, slot)
        v_cache = _cache_update(cache["v"], v, slot)
        out = decode_attention(q, k_cache, v_cache, index, window=window,
                               ring=bool(ring), softcap=cfg.attn_logit_softcap)
        new_cache = {"k": k_cache, "v": v_cache}
    else:
        impl = cfg.attn_impl
        if impl == "auto":
            impl = "flash" if S > 1024 else "full"
        if impl == "flash" and cfg.attn_logit_softcap:
            impl = "brick"   # flash path has no softcap support
        if impl == "flash":
            from repro.kernels.flash_attention.ops import flash_attention
            out = flash_attention(q, k, v, causal, window,
                                  min(cfg.attn_chunk_q, S),
                                  min(cfg.attn_chunk_kv, S),
                                  "pallas" if cfg.use_pallas else "jnp")
        elif impl == "brick":
            out = brick_attention(q, k, v, causal=causal, window=window,
                                  cq=cfg.attn_chunk_q, ck=cfg.attn_chunk_kv,
                                  softcap=cfg.attn_logit_softcap)
        else:
            out = full_attention(q, k, v, causal=causal, window=window,
                                 softcap=cfg.attn_logit_softcap)
        if mode == "prefill" and cache is not None:
            Sc = cache["k"].shape[1]
            if Sc >= S:
                k_cache = _cache_update(cache["k"], k, 0)
                v_cache = _cache_update(cache["v"], v, 0)
            else:  # ring (local window) cache keeps the last Sc tokens
                k_tail = k[:, -Sc:]
                v_tail = v[:, -Sc:]
                roll = jnp.mod(S - Sc + jnp.arange(Sc), Sc)
                k_cache = jnp.take(k_tail, jnp.argsort(roll), axis=1).astype(
                    cache["k"].dtype)
                v_cache = jnp.take(v_tail, jnp.argsort(roll), axis=1).astype(
                    cache["v"].dtype)
            new_cache = {"k": k_cache, "v": v_cache}

    out = shard_act(out, "batch", None, "heads", None)
    dt = x.dtype
    if pad_g:
        out = out.reshape(B, out.shape[1], -1, pad_g[1], out.shape[-1])
        out = out[:, :, :, :pad_g[0]].reshape(B, out.shape[1], -1,
                                              out.shape[-1])
    if cfg.tp_sp and mode != "decode":
        y = L_row_parallel(out.astype(dt), params["wo"].astype(dt),
                           "bshe,hed->bsd", h_model_dim=2)
        if y is not None:
            return shard_act(y, "batch", "seq_act", None), new_cache
    y = jnp.einsum("bshe,hed->bsd", out.astype(dt), params["wo"].astype(dt))
    return shard_act(y, "batch", "seq_act", None), new_cache


def _cache_update(cache: jax.Array, kv: jax.Array, slot) -> jax.Array:
    """Write kv at per-sequence slots.  slot: scalar or (B,) vector —
    continuous batching gives every sequence its own write position."""
    kv = kv.astype(cache.dtype)
    slot = jnp.asarray(slot)
    if slot.ndim == 0:
        return jax.lax.dynamic_update_slice_in_dim(cache, kv, slot, axis=1)
    return jax.vmap(
        lambda c, u, s: jax.lax.dynamic_update_slice_in_dim(c, u, s, axis=0)
    )(cache, kv, slot)


# --------------------------------------------------------------------------- #
# Cross attention (encoder-decoder)
# --------------------------------------------------------------------------- #
def cross_attn_specs(cfg: ModelConfig) -> dict:
    return attn_specs(cfg, cross=True)


def cross_attention_block(params: dict, x: jax.Array, enc_kv: Tuple,
                          cfg: ModelConfig) -> jax.Array:
    """x: (B, St, d); enc_kv = (k, v) precomputed from encoder output."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhe->bshe", x, params["wq"].astype(dt))
    k, v = enc_kv
    B, Sq = q.shape[0], q.shape[1]
    if Sq == 1:
        Hq = q.shape[2]
        Hkv = k.shape[2]
        G = Hq // Hkv
        q5 = q.reshape(B, 1, Hkv, G, q.shape[-1])
        s = jnp.einsum("bqkgd,bskd->bqkgs", q5, k) / math.sqrt(q.shape[-1])
        p = jax.nn.softmax(s.astype(jnp.float32), -1).astype(dt)
        out = jnp.einsum("bqkgs,bskd->bqkgd", p, v).reshape(q.shape)
    elif Sq * k.shape[1] <= 4096 * 4096:
        out = full_attention(q, k, v, causal=False)
    else:
        out = brick_attention(q, k, v, causal=False,
                              cq=cfg.attn_chunk_q, ck=cfg.attn_chunk_kv)
    y = jnp.einsum("bshe,hed->bsd", out.astype(dt), params["wo"].astype(dt))
    return shard_act(y, "batch", "seq_act", None)


def encode_cross_kv(params: dict, enc_out: jax.Array, cfg: ModelConfig):
    dt = enc_out.dtype
    k = jnp.einsum("bsd,dhe->bshe", enc_out, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhe->bshe", enc_out, params["wv"].astype(dt))
    return k, v
