"""Mixture-of-Experts with expert parallelism over the `model` mesh axis.

Two dispatch strategies, both expressed with shard_map so the collective
schedule is explicit:

  * ``a2a``        — tokens are sequence-sharded over the model axis.  Each
                     chip routes its own tokens, builds a capacity-bounded
                     (E, C, d) dispatch buffer and ``all_to_all``s it so every
                     chip receives the slots of its local experts.  This is the
                     TPU-native analogue of the NCCL a2a dispatch used by GPU
                     MoE frameworks: ICI all-to-all instead of NVLink.
  * ``replicated`` — tokens are replicated over the model axis (decode / tiny
                     batches).  Every chip routes all tokens but only executes
                     its local experts, then a psum over the model axis
                     combines expert outputs.  Comm is O(tokens·d), optimal for
                     small N.

Routing is top-k softmax with probability renormalisation and the standard
load-balance auxiliary loss.  Capacity overflow drops tokens (the residual
path keeps them intact); decode-sized batches get dropless capacity.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map

from repro.configs.base import ModelConfig
from repro.models.layers import mlp_specs, mlp_apply
from repro.parallel import sharding as shlib
from repro.parallel.sharding import ParamSpec, shard_act


@partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def _a2a_int8(x: jax.Array, axis: str, split_axis: int, concat_axis: int
              ) -> jax.Array:
    """all_to_all with int8-quantized payload (per-row scale), halving ICI
    dispatch bytes vs bf16.  Straight-through gradient: the backward a2a
    moves full-precision cotangents (fwd-only compression)."""
    return _a2a_int8_fwd(x, axis, split_axis, concat_axis)[0]


def _a2a_int8_fwd(x, axis, split_axis, concat_axis):
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    q = jax.lax.all_to_all(q, axis, split_axis=split_axis,
                           concat_axis=concat_axis, tiled=True)
    s = jax.lax.all_to_all(scale, axis, split_axis=split_axis,
                           concat_axis=concat_axis, tiled=True)
    out = (q.astype(jnp.float32) * s).astype(x.dtype)
    return out, None


def _a2a_int8_bwd(axis, split_axis, concat_axis, res, g):
    # transpose of all_to_all swaps split/concat axes
    gx = jax.lax.all_to_all(g, axis, split_axis=concat_axis,
                            concat_axis=split_axis, tiled=True)
    return (gx,)


_a2a_int8.defvjp(_a2a_int8_fwd, _a2a_int8_bwd)


def moe_specs(cfg: ModelConfig) -> dict:
    E, dff, d = cfg.num_experts, cfg.moe_d_ff, cfg.d_model
    specs = {
        "router": ParamSpec((d, E), ("embed", None), scale=1.0),
        "wi_gate": ParamSpec((E, d, dff), ("experts", "embed", None)),
        "wi_up": ParamSpec((E, d, dff), ("experts", "embed", None)),
        "wo": ParamSpec((E, dff, d), ("experts", None, "embed")),
    }
    if cfg.shared_expert:
        specs["shared"] = mlp_specs(cfg, d_ff=cfg.moe_d_ff)
    return specs


def _route(xf: jax.Array, router_w: jax.Array, k: int
           ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """xf: (N, d) -> (gates (N,k), experts (N,k) int32, probs (N,E) f32)."""
    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    return gates, experts.astype(jnp.int32), probs


def _aux_stats(probs: jax.Array, experts: jax.Array, E: int):
    """Per-shard (f_e, P_e) statistics for the load-balance loss."""
    onehot = jax.nn.one_hot(experts, E, dtype=jnp.float32)    # (N,k,E)
    f = jnp.mean(jnp.sum(onehot, axis=1), axis=0)             # fraction routed
    p = jnp.mean(probs, axis=0)
    return f, p


def _aux_loss(probs: jax.Array, experts: jax.Array, E: int) -> jax.Array:
    """Load-balance loss: E * sum_e f_e * P_e  (Switch Transformer)."""
    k = experts.shape[1]
    f, p = _aux_stats(probs, experts, E)
    return E * jnp.sum(f * p) / k


def _dispatch_compute(xf, gates, experts, keepers, wi_g, wi_u, wo, capacity,
                      e_base, e_count):
    """Scatter tokens into a (e_count, capacity, d) buffer, run experts,
    gather back.  `keepers` optionally masks assignments (replicated mode).

    Returns (out (N, d), dropped fraction proxy)."""
    N, d = xf.shape
    k = gates.shape[1]
    e_flat = experts.reshape(-1)                              # (N*k,)
    t_flat = jnp.repeat(jnp.arange(N, dtype=jnp.int32), k)
    g_flat = gates.reshape(-1)
    local = (e_flat >= e_base) & (e_flat < e_base + e_count)
    if keepers is not None:
        local &= keepers.reshape(-1)
    e_local = jnp.where(local, e_flat - e_base, e_count)      # e_count = trash
    order = jnp.argsort(e_local, stable=True)
    e_s = e_local[order]
    t_s = t_flat[order]
    g_s = g_flat[order]
    counts = jnp.bincount(e_s, length=e_count + 1)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(N * k, dtype=jnp.int32) - starts[e_s].astype(jnp.int32)
    keep = (pos < capacity) & (e_s < e_count)
    dest = jnp.where(keep, e_s * capacity + pos, e_count * capacity)
    x_s = jnp.take(xf, t_s, axis=0) * keep[:, None].astype(xf.dtype)
    buf = jnp.zeros((e_count * capacity + 1, d), xf.dtype)
    buf = buf.at[dest].add(x_s)
    buf = buf[:-1].reshape(e_count, capacity, d)

    h_g = jnp.einsum("ecd,edf->ecf", buf, wi_g.astype(buf.dtype))
    h_u = jnp.einsum("ecd,edf->ecf", buf, wi_u.astype(buf.dtype))
    h = jax.nn.silu(h_g) * h_u
    y = jnp.einsum("ecf,efd->ecd", h, wo.astype(buf.dtype))

    y_flat = jnp.concatenate(
        [y.reshape(e_count * capacity, d), jnp.zeros((1, d), y.dtype)], 0)
    y_tok = jnp.take(y_flat, dest, axis=0) * (
        g_s[:, None].astype(y.dtype) * keep[:, None].astype(y.dtype))
    out = jnp.zeros((N, d), y.dtype).at[t_s].add(y_tok)
    return out


def moe_block(params: dict, x: jax.Array, cfg: ModelConfig
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss)."""
    mesh = shlib.current_mesh()
    rules = shlib.current_rules()
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token

    if mesh is None or "model" not in mesh.shape:
        # single-device path (smoke tests): all experts local
        xf = x.reshape(B * S, d)
        gates, experts, probs = _route(xf, params["router"], k)
        N = B * S
        cap = N if N <= 512 else int(math.ceil(N * k / E * cfg.capacity_factor))
        out = _dispatch_compute(xf, gates, experts, None, params["wi_gate"],
                                params["wi_up"], params["wo"], cap, 0, E)
        aux = _aux_loss(probs, experts, E)
        out = out.reshape(B, S, d)
        if cfg.shared_expert:
            out = out + mlp_apply(params["shared"], x)
        return out, aux

    mp = mesh.shape["model"]
    assert E % mp == 0, (E, mp)
    E_loc = E // mp
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp = int(np.prod([mesh.shape[a] for a in data_axes]))
    bspec = data_axes[0] if len(data_axes) == 1 else data_axes

    batch_shardable = B % dp == 0
    seq_shardable = S % mp == 0 and S >= mp
    strategy = "a2a" if seq_shardable else "replicated"

    B_loc = B // dp if batch_shardable else B
    S_loc = S // mp if strategy == "a2a" else S
    N_loc = B_loc * S_loc
    cap = (N_loc if N_loc <= 256 else
           int(math.ceil(N_loc * k / E * cfg.capacity_factor)))
    cap = max(cap, 1)

    in_x_spec = P(bspec if batch_shardable else None,
                  "model" if strategy == "a2a" else None, None)

    def local_fn(x_l, router_w, wi_g, wi_u, wo):
        m_idx = jax.lax.axis_index("model")
        xf = x_l.reshape(-1, d)
        gates, experts, probs = _route(xf, router_w, k)
        # combine (f, P) across token shards BEFORE the product so the
        # sharded aux equals the global-batch aux exactly
        f_loc, p_loc = _aux_stats(probs, experts, E)
        stat_axes = (data_axes + ("model",) if strategy == "a2a"
                     else data_axes)
        f_g = jax.lax.pmean(f_loc, stat_axes) if stat_axes else f_loc
        p_g = jax.lax.pmean(p_loc, stat_axes) if stat_axes else p_loc
        aux = E * jnp.sum(f_g * p_g) / k
        if strategy != "a2a":
            aux = jax.lax.pmean(aux, ("model",))   # replicate across model
        if strategy == "a2a":
            # full-E buffer, then all_to_all expert dim -> local experts
            buf_out = _moe_a2a(xf, gates, experts, wi_g, wi_u, wo, cap, E,
                               E_loc, d, k)
        else:
            e_base = m_idx * E_loc
            buf_out = _dispatch_compute(xf, gates, experts, None, wi_g, wi_u,
                                        wo, cap, e_base, E_loc)
            buf_out = jax.lax.psum(buf_out, "model")
        return buf_out.reshape(x_l.shape), aux

    def _moe_a2a(xf, gates, experts, wi_g, wi_u, wo, cap, E, E_loc, d, k):
        N = xf.shape[0]
        e_flat = experts.reshape(-1)
        t_flat = jnp.repeat(jnp.arange(N, dtype=jnp.int32), k)
        g_flat = gates.reshape(-1)
        order = jnp.argsort(e_flat, stable=True)
        e_s, t_s, g_s = e_flat[order], t_flat[order], g_flat[order]
        counts = jnp.bincount(e_s, length=E + 1)[:E]
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(N * k, dtype=jnp.int32) - starts[e_s].astype(jnp.int32)
        keep = pos < cap
        dest = jnp.where(keep, e_s * cap + pos, E * cap)
        x_s = jnp.take(xf, t_s, axis=0) * keep[:, None].astype(xf.dtype)
        buf = jnp.zeros((E * cap + 1, d), xf.dtype)
        buf = buf.at[dest].add(x_s).astype(xf.dtype)
        buf = buf[:-1].reshape(E, cap, d)
        # (E, cap, d) -> exchange: each peer gets its E_loc experts' slots
        if cfg.moe_a2a_int8:
            buf = _a2a_int8(buf, "model", 0, 1)               # (E_loc, mp*cap, d)
        else:
            buf = jax.lax.all_to_all(buf, "model", split_axis=0,
                                     concat_axis=1, tiled=True)
        h_g = jnp.einsum("ecd,edf->ecf", buf, wi_g.astype(buf.dtype))
        h_u = jnp.einsum("ecd,edf->ecf", buf, wi_u.astype(buf.dtype))
        h = jax.nn.silu(h_g) * h_u
        y = jnp.einsum("ecf,efd->ecd", h, wo.astype(buf.dtype))
        if cfg.moe_a2a_int8:
            y = _a2a_int8(y, "model", 1, 0)                   # (E, cap, d)
        else:
            y = jax.lax.all_to_all(y, "model", split_axis=1, concat_axis=0,
                                   tiled=True)
        y_flat = jnp.concatenate(
            [y.reshape(E * cap, d), jnp.zeros((1, d), y.dtype)], 0)
        y_tok = jnp.take(y_flat, dest, axis=0) * (
            g_s[:, None].astype(y.dtype) * keep[:, None].astype(y.dtype))
        return jnp.zeros((N, d), y.dtype).at[t_s].add(y_tok)

    out, aux = shard_map(
        local_fn, mesh=mesh,
        in_specs=(in_x_spec, P(None, None), P("model", None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=(in_x_spec, P()),
        check_vma=False,
    )(x, params["router"], params["wi_gate"], params["wi_up"], params["wo"])

    if cfg.shared_expert:
        out = out + mlp_apply(params["shared"], x)
    return shard_act(out, "batch", "seq_act", None), aux
