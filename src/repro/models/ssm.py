"""Mamba2 SSD (state-space duality) block — chunked, MXU-friendly formulation.

Follows the SSD decomposition of arXiv:2405.21060: within chunks of length L the
output is a masked (semiseparable) matmul; across chunks a tiny recurrence on
the (H, P, N) state carries context.  This pure-jnp implementation doubles as
the oracle for the Pallas kernel in ``repro/kernels/ssd``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import rms_norm
from repro.parallel.sharding import ParamSpec, shard_act


def ssd_specs(cfg: ModelConfig) -> dict:
    d, di = cfg.d_model, cfg.d_inner
    g, n, h, w = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_conv_width
    return {
        "wz": ParamSpec((d, di), ("embed", "ssm_inner")),
        "wx": ParamSpec((d, di), ("embed", "ssm_inner")),
        "wB": ParamSpec((d, g * n), ("embed", None)),
        "wC": ParamSpec((d, g * n), ("embed", None)),
        "wdt": ParamSpec((d, h), ("embed", "ssm_heads")),
        "conv_x": ParamSpec((w, di), (None, "ssm_inner"), init="normal", scale=1.0),
        "conv_B": ParamSpec((w, g * n), (None, None)),
        "conv_C": ParamSpec((w, g * n), (None, None)),
        "A_log": ParamSpec((h,), ("ssm_heads",), init="zeros"),
        "D": ParamSpec((h,), ("ssm_heads",), init="ones"),
        "dt_bias": ParamSpec((h,), ("ssm_heads",), init="zeros"),
        "gate_norm": ParamSpec((di,), ("ssm_inner",), init="zeros"),
        "wo": ParamSpec((di, d), ("ssm_inner", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array, state: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv along seq.  x: (B,S,C); w: (W,C).

    Returns (y, new_state) where state holds the last W-1 inputs.
    """
    W = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W))
    new_state = xp[:, -(W - 1):] if W > 1 else xp[:, :0]
    return jax.nn.silu(y), new_state


def _segsum_exp(a_cs: jax.Array) -> jax.Array:
    """exp(cumsum segment differences), lower-triangular.

    a_cs: (..., L) inclusive cumsum of dtA.  Returns (..., L, L) with
    out[..., i, j] = exp(a_cs[i] - a_cs[j]) for i >= j else 0.
    """
    L = a_cs.shape[-1]
    diff = a_cs[..., :, None] - a_cs[..., None, :]
    tri = jnp.tril(jnp.ones((L, L), bool))
    return jnp.where(tri, jnp.exp(diff), 0.0)


def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
             Cm: jax.Array, chunk: int,
             init_state: Optional[jax.Array] = None
             ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD.  x: (B,S,H,P); dt: (B,S,H); A: (H,) (negative);
    Bm/Cm: (B,S,G,N).  Returns (y: (B,S,H,P), final_state: (B,H,P,N))."""
    Bsz, S, H, Pd = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    L = min(chunk, S)
    pad = (-S) % L
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    nc = Sp // L
    rep = H // G

    # expand groups to per-head (all assigned configs use G == 1)
    Bh = jnp.repeat(Bm, rep, axis=2)                      # (B,Sp,H,N)
    Ch = jnp.repeat(Cm, rep, axis=2)

    xc = x.reshape(Bsz, nc, L, H, Pd)
    dtc = dt.reshape(Bsz, nc, L, H).astype(jnp.float32)
    Bc = Bh.reshape(Bsz, nc, L, H, N)
    Cc = Ch.reshape(Bsz, nc, L, H, N)

    dtA = dtc * A.astype(jnp.float32)                     # (B,nc,L,H)
    a_cs = jnp.cumsum(dtA, axis=2)                        # inclusive cumsum
    # decay from j to i within chunk (i >= j): exp(a_cs[i] - a_cs[j])
    Lmat = _segsum_exp(jnp.transpose(a_cs, (0, 1, 3, 2)))  # (B,nc,H,L,L)

    xdt = xc * dtc[..., None].astype(x.dtype)             # (B,nc,L,H,P)

    # ---- intra-chunk (diagonal blocks) ----
    cb = jnp.einsum("bclhn,bcshn->bchls", Cc, Bc)         # (B,nc,H,L,L)
    m = cb.astype(jnp.float32) * Lmat
    y_diag = jnp.einsum("bchls,bcshp->bclhp", m.astype(x.dtype), xdt)

    # ---- chunk states ----
    decay_to_end = jnp.exp(a_cs[:, :, -1:, :] - a_cs)     # (B,nc,L,H)
    states = jnp.einsum("bclhn,bclh,bclhp->bchpn",
                        Bc.astype(jnp.float32),
                        decay_to_end,
                        xdt.astype(jnp.float32))          # (B,nc,H,P,N)

    # ---- inter-chunk recurrence ----
    chunk_decay = jnp.exp(jnp.sum(dtA, axis=2))           # (B,nc,H)
    s0 = (jnp.zeros((Bsz, H, Pd, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def rec(carry, inp):
        st, dk = inp                                      # (B,H,P,N), (B,H)
        new = carry * dk[..., None, None] + st
        return new, carry                                 # emit state BEFORE chunk

    final, prev_states = jax.lax.scan(
        rec, s0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)         # (B,nc,H,P,N)

    # ---- inter-chunk contribution ----
    decay_from_start = jnp.exp(a_cs)                      # (B,nc,L,H)
    y_off = jnp.einsum("bclhn,bchpn,bclh->bclhp",
                       Cc.astype(jnp.float32), prev_states, decay_from_start)

    y = y_diag.astype(jnp.float32) + y_off
    y = y.reshape(Bsz, Sp, H, Pd)[:, :S]
    return y.astype(x.dtype), final


def ssd_block(params: dict, x: jax.Array, cfg: ModelConfig, *,
              mode: str = "train", cache: Optional[dict] = None
              ) -> Tuple[jax.Array, Optional[dict]]:
    """Full Mamba2 block: proj -> conv -> SSD -> gated norm -> out proj."""
    dt_ = x.dtype
    B, S, _ = x.shape
    H, Pd = cfg.ssm_nheads, cfg.ssm_head_dim
    G, N = cfg.ssm_ngroups, cfg.ssm_state

    z = jnp.einsum("bsd,de->bse", x, params["wz"].astype(dt_))
    xs = jnp.einsum("bsd,de->bse", x, params["wx"].astype(dt_))
    Bp = jnp.einsum("bsd,de->bse", x, params["wB"].astype(dt_))
    Cp = jnp.einsum("bsd,de->bse", x, params["wC"].astype(dt_))
    dtp = jnp.einsum("bsd,dh->bsh", x, params["wdt"].astype(dt_))
    xs = shard_act(xs, "batch", None, "ssm_inner")
    z = shard_act(z, "batch", None, "ssm_inner")

    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    dt_act = jax.nn.softplus(dtp.astype(jnp.float32)
                             + params["dt_bias"].astype(jnp.float32))

    if mode == "decode":
        assert cache is not None and S == 1
        xs, conv_x = _conv_step(xs, params["conv_x"], cache["conv_x"])
        Bp, conv_b = _conv_step(Bp, params["conv_B"], cache["conv_b"])
        Cp, conv_c = _conv_step(Cp, params["conv_C"], cache["conv_c"])
        xh = xs.reshape(B, H, Pd)
        Bb = jnp.repeat(Bp.reshape(B, G, N), H // G, axis=1)   # (B,H,N)
        Cb = jnp.repeat(Cp.reshape(B, G, N), H // G, axis=1)
        dt1 = dt_act[:, 0]                                 # (B,H)
        dA = jnp.exp(dt1 * A)                              # (B,H)
        st = cache["ssm"].astype(jnp.float32)
        upd = jnp.einsum("bh,bhp,bhn->bhpn", dt1, xh.astype(jnp.float32),
                         Bb.astype(jnp.float32))
        st = st * dA[..., None, None] + upd
        y = jnp.einsum("bhpn,bhn->bhp", st, Cb.astype(jnp.float32))
        y = y + params["D"].astype(jnp.float32)[None, :, None] * \
            xh.astype(jnp.float32)
        y = y.reshape(B, 1, cfg.d_inner)
        new_cache = {"ssm": st.astype(cache["ssm"].dtype), "conv_x": conv_x,
                     "conv_b": conv_b, "conv_c": conv_c}
    else:
        xs, conv_x = _causal_conv(xs, params["conv_x"].astype(dt_))
        Bp, conv_b = _causal_conv(Bp, params["conv_B"].astype(dt_))
        Cp, conv_c = _causal_conv(Cp, params["conv_C"].astype(dt_))
        xh = xs.reshape(B, S, H, Pd)
        Bv = Bp.reshape(B, S, G, N)
        Cv = Cp.reshape(B, S, G, N)
        if cfg.use_pallas:
            from repro.kernels.ssd.ops import ssd as ssd_op
            y, fin = ssd_op(xh, dt_act, A, Bv, Cv, chunk=cfg.ssd_chunk)
        else:
            y, fin = ssd_scan(xh, dt_act, A, Bv, Cv, chunk=cfg.ssd_chunk)
        y = y + params["D"].astype(y.dtype)[None, None, :, None] * xh.astype(
            y.dtype)
        y = y.reshape(B, S, cfg.d_inner)
        new_cache = None
        if mode == "prefill" and cache is not None:
            new_cache = {"ssm": fin.astype(cache["ssm"].dtype),
                         "conv_x": conv_x.astype(cache["conv_x"].dtype),
                         "conv_b": conv_b.astype(cache["conv_b"].dtype),
                         "conv_c": conv_c.astype(cache["conv_c"].dtype)}

    y = shard_act(y.astype(dt_), "batch", None, "ssm_inner")
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(dt_),
                 params["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["wo"].astype(dt_))
    return shard_act(out, "batch", "seq_act", None), new_cache


def _conv_step(x1: jax.Array, w: jax.Array, state: jax.Array
               ) -> Tuple[jax.Array, jax.Array]:
    """Single-token causal conv.  x1: (B,1,C); state: (B,W-1,C)."""
    xp = jnp.concatenate([state.astype(x1.dtype), x1], axis=1)  # (B,W,C)
    y = jnp.einsum("bwc,wc->bc", xp, w.astype(x1.dtype))[:, None]
    return jax.nn.silu(y), xp[:, 1:].astype(state.dtype)


def ssd_cache_specs(cfg: ModelConfig, batch: int) -> dict:
    H, Pd, G, N, W = (cfg.ssm_nheads, cfg.ssm_head_dim, cfg.ssm_ngroups,
                      cfg.ssm_state, cfg.ssm_conv_width)
    return {
        "ssm": ParamSpec((batch, H, Pd, N), ("batch", "ssm_heads", None, None),
                         dtype=jnp.float32, init="zeros"),
        "conv_x": ParamSpec((batch, W - 1, cfg.d_inner),
                            ("batch", None, "ssm_inner"),
                            dtype=cfg.act_dtype, init="zeros"),
        "conv_b": ParamSpec((batch, W - 1, G * N), ("batch", None, None),
                            dtype=cfg.act_dtype, init="zeros"),
        "conv_c": ParamSpec((batch, W - 1, G * N), ("batch", None, None),
                            dtype=cfg.act_dtype, init="zeros"),
    }
