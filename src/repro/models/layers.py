"""Shared neural building blocks: norms, RoPE (incl. M-RoPE), embeddings, loss."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.parallel.sharding import ParamSpec, shard_act


# --------------------------------------------------------------------------- #
# Norms
# --------------------------------------------------------------------------- #
def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    # variance reduced in f32, but x itself is never materialised as an f32
    # tensor (XLA hoists full-size converts of remat-saved activations out of
    # backward loops otherwise — 4.5 GiB/device on a 48L model).
    dt = x.dtype
    # f32 accumulation without materialising an f32 copy of x
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True, dtype=jnp.float32)
    scale = (jax.lax.rsqrt(var + eps)).astype(dt)
    return x * scale * (1.0 + gamma.astype(dt))


def norm_spec(dim: int) -> ParamSpec:
    # stored as (gamma - 1) so zeros-init == identity
    return ParamSpec((dim,), (None,), init="zeros")


# --------------------------------------------------------------------------- #
# RoPE
# --------------------------------------------------------------------------- #
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)                       # (half,)
    angles = positions[..., None].astype(jnp.float32) * freqs    # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]                          # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * cos - x2f * sin, x2f * cos + x1f * sin], -1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float,
                sections: Tuple[int, ...]) -> jax.Array:
    """Multimodal 3D RoPE (Qwen2-VL).

    x: (B, S, H, D); positions: (3, B, S) with (t, h, w) indices.  The D/2
    rotary frequencies are split into `sections` (sum == D/2); section k uses
    positions[k] as the rotation index.
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(x.shape[-1], theta)                       # (half,)
    # angles per modality: (3, B, S, half)
    angles = positions[..., None].astype(jnp.float32) * freqs
    parts = []
    start = 0
    for k, sec in enumerate(sections):
        parts.append(angles[k, ..., start:start + sec])
        start += sec
    ang = jnp.concatenate(parts, axis=-1)                        # (B, S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * cos - x2f * sin, x2f * cos + x1f * sin], -1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------- #
# Embedding / head
# --------------------------------------------------------------------------- #
def embed_specs(cfg: ModelConfig) -> dict:
    # the token embedding always exists: even embeds-input (VLM/audio) archs
    # embed generated tokens during decode.  fsdp_dim=-2 disables extra FSDP
    # sharding: the lookup runs in a shard_map over the vocab(model) axis and
    # the d_model dim must stay whole per shard.
    d = {"embedding": ParamSpec((cfg.vocab_size, cfg.d_model),
                                ("vocab", "embed"), init="embed", scale=0.02,
                                fsdp_dim=-2)}
    if not cfg.tie_embeddings:
        d["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab_size),
                                 ("embed", "vocab"), scale=1.0)
    d["final_norm"] = norm_spec(cfg.d_model)
    return d


def embed_tokens(params: dict, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Vocab-sharded lookup via shard_map: each model-axis shard gathers the
    ids that fall in its vocab range and a psum combines — the gradient stays
    a (V/mp, d) local scatter instead of a full dense f32 (V, d) per device."""
    from repro.parallel import sharding as shlib
    emb = params["embedding"]
    mesh = shlib.current_mesh()
    V, D = emb.shape
    if mesh is None or "model" not in mesh.shape or V % mesh.shape["model"]:
        x = jnp.take(emb.astype(cfg.act_dtype), tokens, axis=0)
        return shard_act(x, "batch", "seq_act", None)

    from repro.compat import shard_map
    from jax.sharding import PartitionSpec as P
    mp = mesh.shape["model"]
    V_loc = V // mp
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    import numpy as np
    dp = int(np.prod([mesh.shape[a] for a in data_axes])) if data_axes else 1
    bsp = None
    if data_axes and tokens.shape[0] % dp == 0:
        bsp = data_axes[0] if len(data_axes) == 1 else data_axes

    def local(emb_l, tok_l):
        base = jax.lax.axis_index("model") * V_loc
        loc = tok_l - base
        ok = (loc >= 0) & (loc < V_loc)
        safe = jnp.clip(loc, 0, V_loc - 1)
        g = jnp.take(emb_l.astype(cfg.act_dtype), safe, axis=0)
        g = g * ok[..., None].astype(g.dtype)
        return jax.lax.psum(g, "model")

    x = shard_map(local, mesh=mesh,
                  in_specs=(P("model", None), P(bsp, None)),
                  out_specs=P(bsp, None, None))(emb, tokens)
    return shard_act(x, "batch", "seq_act", None)


def lm_logits(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings and "lm_head" not in params:
        w = params["embedding"].astype(cfg.act_dtype).T
    else:
        w = params["lm_head"].astype(cfg.act_dtype)
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    return shard_act(logits, "batch", None, "vocab")


def lm_head_loss(params: dict, x: jax.Array, labels: jax.Array,
                 cfg: ModelConfig,
                 mask: Optional[jax.Array] = None) -> jax.Array:
    """Sequence-chunked softmax cross-entropy.

    Materialising (B, S, V) logits (plus their f32 shadow and the dW matmul
    layouts) costs several GiB/device at 4k x 92k vocab; scanning over seq
    chunks with a checkpointed body keeps the live set to one chunk.
    """
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings and "lm_head" not in params:
        w = params["embedding"].astype(cfg.act_dtype).T
    else:
        w = params["lm_head"].astype(cfg.act_dtype)
    B, S, _ = x.shape
    c = cfg.loss_chunk
    if not c or S <= c:
        logits = shard_act(jnp.einsum("bsd,dv->bsv", x, w),
                           "batch", None, "vocab")
        return cross_entropy(logits, labels, mask)
    if S % c:
        c = S // (S // c)  # keep chunks equal; S is a power of two in practice
    n = S // c

    def body(carry, idx):
        tot, cnt = carry
        xs = jax.lax.dynamic_slice_in_dim(x, idx * c, c, axis=1)
        xs = shard_act(xs, "batch", None, None)
        lbl = jax.lax.dynamic_slice_in_dim(labels, idx * c, c, axis=1)
        logits = shard_act(jnp.einsum("bsd,dv->bsv", xs, w),
                           "batch", None, "vocab").astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lbl[..., None], axis=-1)[..., 0]
        nll = lse - gold
        if mask is not None:
            mk = jax.lax.dynamic_slice_in_dim(mask, idx * c, c, axis=1)
            mkf = mk.astype(jnp.float32)
            return (tot + jnp.sum(nll * mkf), cnt + jnp.sum(mkf)), None
        return (tot + jnp.sum(nll), cnt + jnp.float32(nll.size)), None

    body = jax.checkpoint(body)
    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        jnp.arange(n))
    return tot / jnp.maximum(cnt, 1.0)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token NLL; logits (B, S, V), labels (B, S) int32."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# --------------------------------------------------------------------------- #
# Row-parallel projection with explicit reduce-scatter (TP-SP)
# --------------------------------------------------------------------------- #
def row_parallel_proj(h: jax.Array, w: jax.Array, eq: str,
                      h_model_dim: int) -> Optional[jax.Array]:
    """y = einsum(eq, h, w) with the contraction dim model-sharded, emitting
    ``psum_scatter`` over the sequence dim instead of XLA's all-reduce+slice
    (halves the dominant collective's bytes).  Returns None if the shapes
    don't divide the mesh (caller falls back to the einsum+constraint path).
    """
    from repro.parallel import sharding as shlib
    import numpy as np
    mesh = shlib.current_mesh()
    if mesh is None or "model" not in mesh.shape:
        return None
    mp = mesh.shape["model"]
    B, S = h.shape[0], h.shape[1]
    if mp == 1 or S % mp or w.shape[0] * (w.shape[1] if w.ndim == 3 else 1) \
            % mp:
        return None
    if h.shape[h_model_dim] % mp:
        return None
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp = int(np.prod([mesh.shape[a] for a in data_axes])) if data_axes else 1
    if data_axes and B % dp:
        return None
    bsp = (None if not data_axes else
           (data_axes[0] if len(data_axes) == 1 else data_axes))

    from repro.compat import shard_map
    from jax.sharding import PartitionSpec as P

    h_spec = [bsp] + [None] * (h.ndim - 1)
    h_spec[h_model_dim] = "model"
    w_spec = ["model"] + [None] * (w.ndim - 1)

    def f(h_l, w_l):
        part = jnp.einsum(eq, h_l, w_l)
        return jax.lax.psum_scatter(part, "model", scatter_dimension=1,
                                    tiled=True)

    return shard_map(f, mesh=mesh,
                     in_specs=(P(*h_spec), P(*w_spec)),
                     out_specs=P(bsp, "model", None),
                     check_vma=False)(h, w)


# --------------------------------------------------------------------------- #
# Dense MLP (SwiGLU)
# --------------------------------------------------------------------------- #
def mlp_specs(cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    dff = d_ff or cfg.d_ff
    return {
        "wi_gate": ParamSpec((cfg.d_model, dff), ("embed", "mlp")),
        "wi_up": ParamSpec((cfg.d_model, dff), ("embed", "mlp")),
        "wo": ParamSpec((dff, cfg.d_model), ("mlp", "embed")),
    }


def col_parallel_mlp_in(x: jax.Array, wg: jax.Array, wu: jax.Array):
    """Column-parallel wi_gate/wi_up with the sequence all-gather INSIDE a
    shard_map, so its transpose lowers to psum_scatter (not all-reduce) and
    one gather feeds both matmuls.  Returns None if shapes don't divide."""
    from repro.parallel import sharding as shlib
    import numpy as np
    mesh = shlib.current_mesh()
    if mesh is None or "model" not in mesh.shape:
        return None
    mp = mesh.shape["model"]
    B, S = x.shape[0], x.shape[1]
    if mp == 1 or S % mp or wg.shape[1] % mp:
        return None
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp = int(np.prod([mesh.shape[a] for a in data_axes])) if data_axes else 1
    if data_axes and B % dp:
        return None
    bsp = (None if not data_axes else
           (data_axes[0] if len(data_axes) == 1 else data_axes))
    from repro.compat import shard_map
    from jax.sharding import PartitionSpec as P

    def f(x_l, wg_l, wu_l):
        xg = jax.lax.all_gather(x_l, "model", axis=1, tiled=True)
        return (jnp.einsum("bsd,df->bsf", xg, wg_l),
                jnp.einsum("bsd,df->bsf", xg, wu_l))

    return shard_map(f, mesh=mesh,
                     in_specs=(P(bsp, "model", None), P(None, "model"),
                               P(None, "model")),
                     out_specs=(P(bsp, None, "model"), P(bsp, None, "model")),
                     check_vma=False)(x, wg, wu)


def mlp_apply(params: dict, x: jax.Array, tp_sp: bool = False) -> jax.Array:
    dt = x.dtype
    pair = (col_parallel_mlp_in(x, params["wi_gate"].astype(dt),
                                params["wi_up"].astype(dt))
            if tp_sp else None)
    if pair is not None:
        gate, up = pair
    else:
        gate = jnp.einsum("bsd,df->bsf", x, params["wi_gate"].astype(dt))
        up = jnp.einsum("bsd,df->bsf", x, params["wi_up"].astype(dt))
    h = jax.nn.silu(gate) * up
    h = shard_act(h, "batch", None, "mlp")
    if tp_sp:
        out = row_parallel_proj(h, params["wo"].astype(dt), "bsf,fd->bsd",
                                h_model_dim=2)
        if out is not None:
            return shard_act(out, "batch", "seq_act", None)
    out = jnp.einsum("bsf,fd->bsd", h, params["wo"].astype(dt))
    return shard_act(out, "batch", "seq_act", None)
