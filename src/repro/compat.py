"""Version compatibility shims.

`jax.shard_map` graduated from `jax.experimental.shard_map` in jax 0.6;
this repo targets the new spelling (including its `check_vma` kwarg) but
must also run on jax 0.4.x where only the experimental module exists and
the kwarg is called `check_rep`.
"""
from __future__ import annotations

try:  # jax >= 0.6
    from jax import shard_map as shard_map  # type: ignore[attr-defined]
except ImportError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True, **kwargs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma, **kwargs)

__all__ = ["shard_map"]
