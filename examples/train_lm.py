"""End-to-end LM training driver.

Default: a ~20M-param granite-family model for 100 steps on CPU (minutes).
`--size 100m --steps 300` gives the full ~100M x few-hundred-step run on a
real accelerator; `--arch` selects any assigned architecture family.

  PYTHONPATH=src python examples/train_lm.py [--size 20m|100m] [--steps N]
"""
import argparse

from repro.configs.base import GroupSpec, LayerSpec, get_config
from repro.optim.adamw import AdamWConfig
from repro.training.trainer import Trainer, TrainerConfig

SIZES = {
    # name: (layers, d_model, heads, kv, head_dim, d_ff, vocab)
    "tiny": (2, 64, 4, 2, 16, 128, 512),
    "20m": (4, 256, 8, 4, 32, 1024, 8192),
    "100m": (8, 640, 10, 5, 64, 2560, 32768),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--size", default="20m", choices=list(SIZES))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    L, d, h, kv, hd, ff, v = SIZES[args.size]
    cfg = get_config(args.arch).replace(
        d_model=d, num_heads=h, num_kv_heads=kv, head_dim=hd, d_ff=ff,
        vocab_size=v, groups=(GroupSpec((LayerSpec(),), L),),
        attn_chunk_q=128, attn_chunk_kv=128, remat="none", loss_chunk=0)
    from repro.models.model import count_params
    print(f"{args.arch} @ {args.size}: {count_params(cfg) / 1e6:.1f}M params")

    tc = TrainerConfig(batch=args.batch, seq=args.seq, steps=args.steps,
                       ckpt_every=max(args.steps // 4, 1),
                       ckpt_dir=args.ckpt_dir, log_every=10, sdc_every=50)
    tr = Trainer(cfg, AdamWConfig(lr=1e-3, warmup_steps=20,
                                  total_steps=args.steps), tc)
    tr.init()
    hist = tr.run()
    losses = [h["loss"] for h in hist]
    print(f"\nloss: first5={sum(losses[:5]) / 5:.3f} "
          f"last5={sum(losses[-5:]) / 5:.3f}")
    print(f"checkpoints at {args.ckpt_dir}: {tr.store.steps()}")
    print(f"SDC sentinel reports: {len(tr.sdc.reports)}")


if __name__ == "__main__":
    main()
