"""Quickstart: train a tiny LM with the full production stack on CPU.

Runs the same code path a 512-chip job uses — leased data pieces, heartbeats,
async checkpointing — just with a reduced model and no mesh.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs.base import get_config, reduced_config
from repro.optim.adamw import AdamWConfig
from repro.training.trainer import Trainer, TrainerConfig


def main():
    cfg = reduced_config(get_config("granite-8b"))
    tc = TrainerConfig(batch=8, seq=64, steps=30, ckpt_every=10,
                       ckpt_dir="/tmp/repro_quickstart_ckpt", log_every=5)
    tr = Trainer(cfg, AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=30),
                 tc)
    tr.init()
    hist = tr.run()
    print(f"\nloss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
          f"over {len(hist)} steps")
    print("checkpoints:", tr.store.steps())
    print("per-piece (d, w) units flowed back through the coordinator, e.g.:",
          {k: round(v, 4) if isinstance(v, float) else v
           for k, v in hist[-1].items()})


if __name__ == "__main__":
    main()
