"""Fault-tolerance walkthrough: train, kill a member, re-mesh, resume.

Demonstrates the paper's liveness (t, f) + lease machinery driving the
framework's elastic restart: checkpoints survive, leases re-queue, the mesh
plan shrinks to the largest balanced pod count, and training resumes from
the last committed step with bit-identical state.

  PYTHONPATH=src python examples/elastic_failover.py
"""
import tempfile

import jax
import numpy as np

from repro.cluster.elastic import plan_resize
from repro.configs.base import get_config, reduced_config
from repro.optim.adamw import AdamWConfig
from repro.training.trainer import Trainer, TrainerConfig


def main():
    ckpt = tempfile.mkdtemp(prefix="elastic_ckpt_")
    cfg = reduced_config(get_config("qwen3-14b"))
    opt = AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=40)

    print("phase 1: 8-pod job trains to step 20 (checkpoint every 10)")
    tr = Trainer(cfg, opt, TrainerConfig(batch=4, seq=32, steps=20,
                                         ckpt_every=10, ckpt_dir=ckpt,
                                         log_every=10))
    tr.init(seed=0)
    tr.run()

    print("\nphase 2: pod5 misses f=3 heartbeats of t -> declared dead")
    plan = tr.on_member_dead("pod5", alive_pods=7)
    print(f"  resize plan: {plan.old_pods} pods -> {plan.new_pods} "
          f"(mesh {plan.mesh_shape}, reshard={plan.reshard}, "
          f"batch x{plan.batch_scale:.2f})")

    print("\nphase 3: restart on the new mesh; torrent-restore checkpoint")
    tr2 = Trainer(cfg, opt, TrainerConfig(batch=4, seq=32, steps=40,
                                          ckpt_every=10, ckpt_dir=ckpt,
                                          log_every=10))
    tr2.init(seed=0)          # restores step 20, pipeline state included
    assert int(tr2.state["step"]) == 20
    same = all(np.allclose(np.asarray(a), np.asarray(b)) for a, b in zip(
        jax.tree_util.tree_leaves(tr.state),
        jax.tree_util.tree_leaves(tr2.state)))
    print(f"  restored state identical: {same}; resuming to step 40")
    hist = tr2.run()
    print(f"  final loss {hist[-1]['loss']:.4f} at step "
          f"{int(tr2.state['step'])}")


if __name__ == "__main__":
    main()
