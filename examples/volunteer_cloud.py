"""The paper, live: a P2P torrent-like volunteer cloud finding primes.

One tracking server, one seeder agent publishing a prime-search application
(exhaustion method, as in the paper), and three leecher agents that REQ
parts, RUN them for real (threads), and return results for majority-vote
validation.  Seed/Leech directories (Fig. 3) are materialised on disk.

  PYTHONPATH=src python examples/volunteer_cloud.py
"""
import tempfile

from repro.core import (Agent, AgentConfig, ThreadRuntime, TrackerConfig,
                        TrackerServer, make_prime_app)


def main():
    root = tempfile.mkdtemp(prefix="volunteer_cloud_")
    rt = ThreadRuntime(n_workers=3)
    rt.add_node(TrackerServer(config=TrackerConfig(ping_interval_s=0.25)))

    host = Agent("seederY", config=AgentConfig(
        work_timeout_s=20.0, status_interval_s=0.25, retry_s=0.1,
        root_dir=root))
    rt.add_node(host)
    app = make_prime_app("primes_3_to_60k", "seederY", 3, 60_000, n_parts=24)
    host.host_app(app)

    for name in ("leecherX", "leecherZ", "leecherW"):
        rt.add_node(Agent(name, config=AgentConfig(
            work_timeout_s=20.0, status_interval_s=0.25, retry_s=0.1,
            root_dir=root)))

    print(f"cloud up (dirs under {root}); crunching ...")
    rt.run(until_s=60.0, stop_when=lambda: app.done)

    assert app.done, "application did not finish"
    n_primes = sum(len(p.results[0][1]) for p in app.parts)
    m = host.metrics[app.app_id]
    print(f"done: {n_primes} primes <= 60000 found "
          f"(primes in [3, 60000]: 6056)")
    print(f"published units: d={m.d / 1e6:.2f}MB p={m.p} w={m.w * 1e3:.1f}ms")
    for nid in ("leecherX", "leecherZ", "leecherW"):
        a = rt.nodes[nid]
        print(f"  {nid}: cycles={a.completed_cycles[app.app_id]} "
              f"time={a.leech_time[app.app_id]:.2f}s")


if __name__ == "__main__":
    main()
