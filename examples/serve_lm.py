"""End-to-end serving driver: batched requests through the continuous-
batching engine, with the paper's (d, p, w) units published per bucket.

  PYTHONPATH=src python examples/serve_lm.py [--arch qwen3-14b]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_config, reduced_config
from repro.models import model as M
from repro.parallel.sharding import init_params
from repro.serving.engine import ServeConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--requests", type=int, default=12)
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    params = init_params(jax.random.PRNGKey(0), M.model_param_specs(cfg))
    eng = ServingEngine(cfg, params, ServeConfig(slots=4, max_len=128))

    rng = np.random.RandomState(7)
    reqs = []
    for i in range(args.requests):
        plen = int(rng.choice([4, 8, 24]))
        eng.submit(rng.randint(0, cfg.vocab_size, plen).astype(np.int32),
                   max_new=8)
    reqs = list(eng.queue)
    t0 = time.monotonic()
    while eng.queue or eng.active:
        eng.step()
    dt = time.monotonic() - t0
    toks = sum(len(r.out_tokens) for r in reqs)
    lat = [r.finished - r.arrived for r in reqs]
    print(f"{args.arch} (reduced): {len(reqs)} reqs, {toks} tokens, "
          f"{dt:.2f}s wall, p50 latency {sorted(lat)[len(lat) // 2]:.2f}s")
    print("published (d,p,w) per prompt bucket "
          "(the tracker-list analogue for admission):")
    for b, row in sorted(eng.published_units().items()):
        print(f"  bucket<={b:3d}: d={row['d']:7.0f}B p={row['p']:2d} "
              f"w={row['w']:.3f}s")


if __name__ == "__main__":
    main()
